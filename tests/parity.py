"""Unified cross-path parity harness (DESIGN.md §15).

Every execution-path axis in the stack promises the same contract:
bit-identical per-member partitions AND cuts whichever route carries
the work.  The axes:

* ``coarsen``     — ``REPRO_COARSEN_PATH`` (host / device);
* ``mutate``      — ``REPRO_MUTATE_PATH`` (batch / loop);
* ``pop_shard``   — ``REPRO_POP_SHARD`` (off / chunk / mesh), passed to
  the engines as the ``shard=`` override;
* ``model_shard`` — ``REPRO_MODEL_SHARD`` (off / mesh), passed as the
  ``model_shard=`` override;
* ``sched``       — ``REPRO_SCHED`` (static / bandit).  Only ``static``
  belongs in bit-identity grids (it must be byte-for-byte the pre-
  scheduler program under every other axis); ``bandit`` is replay-
  deterministic, not clock-free, and is pinned by its own trace tests.

Before this harness every test file re-implemented the scaffolding
(force one path, run the workload, compare partitions and cuts against
the all-off baseline).  This module consolidates it:

* :class:`PathCombo` — one point on the path grid; env-var axes are
  pinned around the run, shard axes are read by the workload from the
  combo itself;
* :func:`grid` — the cartesian product of the declared axes;
* :func:`params` — ``pytest.param`` list with readable ids and
  per-combo skip/waiver markers;
* :func:`run` — execute a workload under a combo;
* :func:`assert_parity` / :func:`check_grid` — the bit-identity bar.

A *workload* is any callable ``workload(combo) -> (parts, cuts)``
(anything ``np.asarray`` accepts).  The canonical shape::

    COMBOS = parity.grid(pop_shard=(None, "chunk", "mesh"),
                         model_shard=(None, "mesh"))

    @pytest.fixture(scope="module")
    def baseline():
        return parity.run(workload, parity.BASELINE)

    @pytest.mark.parametrize("combo", parity.params(COMBOS))
    def test_paths_bit_equal(baseline, combo):
        parity.assert_parity(parity.run(workload, combo), baseline,
                             label=combo.id)

The in-process grids force each path explicitly, so they are meaningful
at ANY device count: on the single-device tier-1 lane the mesh paths run
through a (1, 1) mesh (the shard_map machinery itself is exercised); on
the multidevice CI lanes (``--xla_force_host_platform_device_count=8``,
optionally ``REPRO_POP_MESH_MODEL=2``) the same grids cover real
cross-device sharding of both the population and the structure.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
from typing import Callable, Iterable, Optional, Sequence, Tuple

import numpy as np
import pytest

# axis name -> env var for the axes routed through the environment;
# pop_shard/model_shard are explicit kwargs on every engine entry point,
# so the workload reads those off the combo instead
AXES = ("coarsen", "mutate", "pop_shard", "model_shard", "sched")
_ENV_AXES = {"coarsen": "REPRO_COARSEN_PATH",
             "mutate": "REPRO_MUTATE_PATH",
             "sched": "REPRO_SCHED"}


@dataclasses.dataclass(frozen=True)
class PathCombo:
    """One point on the path grid.  ``None`` leaves an axis at its
    engine default (which every grid uses as the baseline meaning)."""

    coarsen: Optional[str] = None
    mutate: Optional[str] = None
    pop_shard: Optional[str] = None
    model_shard: Optional[str] = None
    sched: Optional[str] = None

    @property
    def id(self) -> str:
        bits = [f"{a}={getattr(self, a)}" for a in AXES
                if getattr(self, a) is not None]
        return "-".join(bits) or "default"

    @contextlib.contextmanager
    def applied(self):
        """Pin the env-var axes for the duration of the run."""
        saved = {}
        try:
            for axis, var in _ENV_AXES.items():
                val = getattr(self, axis)
                if val is not None:
                    saved[var] = os.environ.get(var)
                    os.environ[var] = val
            yield self
        finally:
            for var, old in saved.items():
                if old is None:
                    os.environ.pop(var, None)
                else:
                    os.environ[var] = old


BASELINE = PathCombo()

Workload = Callable[[PathCombo], Tuple]
Waiver = Tuple[Callable[[PathCombo], bool], str]


def grid(coarsen: Sequence[Optional[str]] = (None,),
         mutate: Sequence[Optional[str]] = (None,),
         pop_shard: Sequence[Optional[str]] = (None,),
         model_shard: Sequence[Optional[str]] = (None,),
         sched: Sequence[Optional[str]] = (None,)):
    """Cartesian grid over the declared axes (undeclared axes stay at
    the engine default in every combo)."""
    return [PathCombo(*vals) for vals in itertools.product(
        coarsen, mutate, pop_shard, model_shard, sched)]


def params(combos: Iterable[PathCombo],
           waivers: Iterable[Waiver] = ()):
    """``pytest.param`` list with combo ids; a waiver ``(pred, reason)``
    turns every matching combo into a skip with that reason."""
    out = []
    for combo in combos:
        marks = [pytest.mark.skip(reason=f"waived: {reason}")
                 for pred, reason in waivers if pred(combo)]
        out.append(pytest.param(combo, id=combo.id, marks=marks))
    return out


def run(workload: Workload, combo: PathCombo):
    """Run ``workload`` under ``combo`` and normalize the result."""
    with combo.applied():
        parts, cuts = workload(combo)
    return np.asarray(parts), np.asarray(cuts)


def assert_parity(got, want, label: str = ""):
    """The bar: partitions AND cuts bit-equal (no tolerance — integer
    exactness is the §15 design invariant, not an approximation)."""
    gp, gc = got
    wp, wc = want
    np.testing.assert_array_equal(
        gp, wp, err_msg=f"[{label}] partitions diverged from baseline")
    np.testing.assert_array_equal(
        gc, wc, err_msg=f"[{label}] cuts diverged from baseline")


def check_grid(workload: Workload, combos: Iterable[PathCombo],
               baseline: PathCombo = BASELINE):
    """One-call form: run the baseline once, then every combo against
    it.  Prefer :func:`params` + a module fixture in test files (each
    combo reports separately); this form suits subprocess lanes."""
    want = run(workload, baseline)
    for combo in combos:
        assert_parity(run(workload, combo), want, label=combo.id)
    return want
