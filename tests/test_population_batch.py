"""The batched population engine vs the per-member loop it replaced.

The acceptance bar for the batched path: at a fixed seed, every member's
refined partition AND cut must be IDENTICAL (bit-for-bit on the
integer-weight fixtures) to running the scalar ``lp_refine``/``fm_refine``
loop member by member — batching buys wall-clock, never answers.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import metrics, refine
from repro.core.hypergraph import Hypergraph


ALPHA = 7


def _population(hg, k, eps, seed):
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(ALPHA):
        p = rng.integers(0, k, hg.n).astype(np.int32)
        parts.append(refine.rebalance(hg.vertex_weights, p, k, eps))
    return parts


def _looped_reference(hga, parts, k, eps, max_iters, fm):
    out_p, out_c = [], []
    for p in parts:
        q, c = refine.lp_refine(hga, p, k, eps, max_iters=max_iters)
        if fm:
            q, c = refine.fm_refine(hga, q, k, eps)
        out_p.append(np.asarray(q))
        out_c.append(c)
    return out_p, out_c


@pytest.mark.parametrize("seed,k", [(0, 2), (1, 4), (2, 7)])
def test_batched_equals_looped_tiny(tiny_hg, seed, k):
    """Per-member cuts and partitions bit-for-bit equal on tiny_hg."""
    eps = 0.10
    hga = tiny_hg.arrays()
    parts = _population(tiny_hg, k, eps, seed)
    ref_p, ref_c = _looped_reference(hga, [p.copy() for p in parts],
                                     k, eps, max_iters=16, fm=True)
    bat_p, bat_c = refine.refine_population(
        hga, [p.copy() for p in parts], k, eps, max_iters=16)
    np.testing.assert_array_equal(np.asarray(ref_c), bat_c)
    for a in range(ALPHA):
        np.testing.assert_array_equal(ref_p[a], bat_p[a])


def test_batched_equals_looped_lp_only(small_hg):
    """LP tier alone (the fine-level path) on the larger fixture."""
    k, eps = 8, 0.08
    hga = small_hg.arrays()
    parts = _population(small_hg, k, eps, seed=3)
    ref_p, ref_c = _looped_reference(hga, [p.copy() for p in parts],
                                     k, eps, max_iters=6, fm=False)
    bat_p, bat_c = refine.lp_refine_population(
        hga, [p.copy() for p in parts], k, eps, max_iters=6)
    np.testing.assert_array_equal(np.asarray(ref_c), bat_c)
    for a in range(ALPHA):
        np.testing.assert_array_equal(ref_p[a], bat_p[a])


@pytest.mark.parametrize("fixture,k,eps", [
    ("tiny_hg", 4, 0.10), ("small_hg", 8, 0.08),
])
def test_population_refine_postconditions(request, fixture, k, eps):
    """Batched refinement never unbalances and never worsens any member."""
    hg = request.getfixturevalue(fixture)
    hga = hg.arrays()
    parts = _population(hg, k, eps, seed=5)
    cuts0 = np.asarray(metrics.cutsize_population(
        hga, refine.pad_parts(parts, hga.n_pad), k))
    new_parts, new_cuts = refine.refine_population(hga, parts, k, eps,
                                                   max_iters=8)
    for a in range(ALPHA):
        assert new_cuts[a] <= cuts0[a] + 1e-6
        assert bool(metrics.is_balanced(
            hga, jnp.asarray(new_parts[a]), k, eps))
        # reported cut is the real cut
        assert new_cuts[a] == pytest.approx(float(metrics.cutsize_jit(
            hga, jnp.asarray(new_parts[a]), k)))


def test_lp_refine_postconditions_scalar_matches_population_row(tiny_hg):
    """A population of one goes through the same dispatch path vcycle
    uses — it must agree with the scalar API exactly."""
    k, eps = 4, 0.10
    hga = tiny_hg.arrays()
    p = _population(tiny_hg, k, eps, seed=9)[0]
    sp, sc = refine.lp_refine(hga, p.copy(), k, eps, max_iters=8)
    bp, bc = refine.lp_refine_population(hga, p.copy()[None, :], k, eps,
                                         max_iters=8)
    assert float(sc) == bc[0]
    np.testing.assert_array_equal(np.asarray(sp), bp[0])


def test_population_metrics_match_scalar(tiny_hg):
    """Batched metric entry points == scalar entry points per member."""
    rng = np.random.default_rng(0)
    k = 4
    hga = tiny_hg.arrays()
    parts = refine.pad_parts(
        [rng.integers(0, k, tiny_hg.n).astype(np.int32)
         for _ in range(5)], hga.n_pad)
    cuts = np.asarray(metrics.cutsize_population(hga, parts, k))
    gains = np.asarray(metrics.gain_matrix_population(hga, parts, k))
    lams = np.asarray(metrics.connectivity_population(hga, parts, k))
    bws = np.asarray(metrics.block_weights_population(hga, parts, k))
    for a in range(5):
        assert cuts[a] == pytest.approx(float(
            metrics.cutsize_jit(hga, parts[a], k)))
        np.testing.assert_allclose(
            gains[a], np.asarray(metrics.gain_matrix_jit(hga, parts[a], k)),
            atol=1e-5)
        np.testing.assert_array_equal(
            lams[a], np.asarray(metrics.connectivity_jit(hga, parts[a], k)))
        np.testing.assert_allclose(
            bws[a], np.asarray(metrics.block_weights_jit(hga, parts[a], k)))


def test_edge_distance_matrix_matches_pairwise(tiny_hg):
    rng = np.random.default_rng(2)
    k = 4
    hga = tiny_hg.arrays()
    parts = refine.pad_parts(
        [rng.integers(0, k, tiny_hg.n).astype(np.int32)
         for _ in range(4)], hga.n_pad)
    dmat = np.asarray(metrics.edge_distance_matrix(hga, parts, k))
    assert dmat.shape == (4, 4)
    for i in range(4):
        for j in range(4):
            want = int(metrics.edge_distance_jit(
                hga, parts[i], parts[j], k))
            assert dmat[i, j] == want
    assert (np.diag(dmat) == 0).all()
    np.testing.assert_array_equal(dmat, dmat.T)


def test_impart_contains_no_per_member_refinement_loop():
    """Structural guard: the driver must stay batched.  The refinement
    section of impart_partition may not loop over cfg.alpha."""
    import inspect
    from repro.core import impart as impart_mod
    src = inspect.getsource(impart_mod.impart_partition)
    assert "for a in range(cfg.alpha)" not in src
    assert "refine_population" in src


def test_impart_batched_end_to_end_small():
    """Full driver on a small instance: valid balanced output, population
    cuts tracked for all members."""
    from repro.core import ImpartConfig, impart_partition
    rng = np.random.default_rng(1)
    edges = [rng.choice(120, size=int(rng.integers(2, 5)), replace=False)
             for _ in range(240)]
    hg = Hypergraph.from_edge_lists(edges, n=120)
    cfg = ImpartConfig(k=4, eps=0.10, alpha=4, beta=2, seed=0,
                       final_vcycles=0)
    res = impart_partition(hg, cfg)
    assert res.part.shape == (hg.n,)
    assert len(res.population_cuts) == 4
    hga = hg.arrays()
    assert bool(metrics.is_balanced(
        hga, refine.pad_part(res.part, hga.n_pad), cfg.k, cfg.eps))
    assert res.cut == pytest.approx(float(metrics.cutsize_jit(
        hga, refine.pad_part(res.part, hga.n_pad), cfg.k)))
    assert res.cut == pytest.approx(min(res.population_cuts))
