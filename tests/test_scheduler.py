"""Operator scheduler (DESIGN.md §16): env routing, replay determinism,
static-path bit-identity, reward accounting, serving integration.

The contract under test:

* ``REPRO_SCHED=static`` (and ``auto``/unset) is byte-for-byte the
  pre-scheduler program under every other path axis (the ``sched``
  axis of ``tests/parity.py``);
* a ``bandit`` run is wall-clock-adaptive but REPLAYABLE: feeding its
  logged :class:`SchedulerTrace` back through
  ``ImpartConfig.sched_replay`` reproduces partition, cut and arm
  sequence exactly, with the clock never consulted;
* rewards are an accounting identity (improvement per wall second, and
  improvements telescope to the run's total cut gain);
* scheduler state snapshots/restores exactly (same RNG stream, same
  statistics) and rides the service's checkpoint path through a device
  loss.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import ImpartConfig, impart_partition
from repro.core import scheduler as sched_mod
from repro.core.hypergraph import Hypergraph
from repro.core.scheduler import (OperatorScheduler, SchedulerTrace,
                                  resolve_sched, sched_path,
                                  sched_prng_seed)
from tests import parity

ALPHA, BETA, K = (3, 2, 4)


def _hg(n=120, m=240, seed=1):
    rng = np.random.default_rng(seed)
    edges = [rng.choice(n, size=int(rng.integers(2, 5)), replace=False)
             for _ in range(m)]
    return Hypergraph.from_edge_lists(edges, n=n)


def _cfg(**kw):
    kw.setdefault("k", K)
    kw.setdefault("eps", 0.10)
    kw.setdefault("alpha", ALPHA)
    kw.setdefault("beta", BETA)
    kw.setdefault("seed", 0)
    kw.setdefault("final_vcycles", 0)
    return ImpartConfig(**kw)


# --------------------------------------------------------------------------
# env routing + one-time warnings
# --------------------------------------------------------------------------
def test_sched_env_routing(monkeypatch):
    monkeypatch.delenv("REPRO_SCHED", raising=False)
    assert sched_path() == "static"          # auto = static
    monkeypatch.setenv("REPRO_SCHED", "bandit")
    assert sched_path() == "bandit"
    assert resolve_sched(None) == "bandit"   # None defers to env
    assert resolve_sched("static") == "static"  # explicit wins
    with pytest.raises(ValueError, match="unknown sched path"):
        resolve_sched("roundrobin")


def test_sched_env_warns_once(monkeypatch):
    monkeypatch.setenv("REPRO_SCHED", "banditt")
    with pytest.warns(UserWarning, match="REPRO_SCHED"):
        assert sched_path() == "static"
    with warnings.catch_warnings():          # warn-once per value
        warnings.simplefilter("error")
        assert sched_path() == "static"


def test_sched_seed_env(monkeypatch):
    monkeypatch.delenv("REPRO_SCHED_SEED", raising=False)
    base = sched_prng_seed(7)
    assert base == sched_prng_seed(7)        # crc32-derived, stable
    assert base != sched_prng_seed(8)
    monkeypatch.setenv("REPRO_SCHED_SEED", "12345")
    import zlib
    # explicit override replaces the config seed in the derivation
    assert sched_prng_seed(7) == zlib.crc32(b"sched:12345")
    monkeypatch.setenv("REPRO_SCHED_SEED", "not-an-int")
    with pytest.warns(UserWarning, match="REPRO_SCHED_SEED"):
        assert sched_prng_seed(7) == base    # bad value falls back


# --------------------------------------------------------------------------
# static path: byte-for-byte the pre-scheduler program (parity grid)
# --------------------------------------------------------------------------
HG_PARITY = _hg(seed=3)
COMBOS = parity.grid(sched=(None, "static"), pop_shard=(None, "chunk"))


def _workload(combo):
    res = impart_partition(HG_PARITY, _cfg(pop_shard=combo.pop_shard))
    return res.part, [res.cut]


@pytest.fixture(scope="module")
def parity_baseline():
    return parity.run(_workload, parity.BASELINE)


@pytest.mark.parametrize("combo", parity.params(COMBOS))
def test_static_paths_bit_equal(parity_baseline, combo):
    parity.assert_parity(parity.run(_workload, combo), parity_baseline,
                         label=combo.id)


# --------------------------------------------------------------------------
# bandit: replay determinism + reward accounting
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def bandit_run():
    hg = _hg(seed=2)
    cfg = _cfg(sched="bandit", seed=5, final_vcycles=1)
    return hg, cfg, impart_partition(hg, cfg)


def test_bandit_trace_replays_bit_identical(bandit_run):
    hg, cfg, live = bandit_run
    trace = live.sched_trace
    assert trace is not None and trace.decisions
    # JSON round-trip: the wire shape a trace has on a benchmark row
    wire = SchedulerTrace.from_json(json.loads(json.dumps(
        trace.to_json())))
    replay = impart_partition(hg, ImpartConfig(
        k=cfg.k, eps=cfg.eps, alpha=cfg.alpha, beta=cfg.beta,
        seed=cfg.seed, final_vcycles=cfg.final_vcycles,
        sched="bandit", sched_replay=wire))
    np.testing.assert_array_equal(replay.part, live.part)
    assert replay.cut == live.cut
    assert (replay.sched_trace.arm_sequence()
            == trace.arm_sequence())
    assert replay.sched_trace.final_vcycles == trace.final_vcycles


def test_bandit_uses_vcycle_phase(bandit_run):
    # final_vcycles=1: in-vcycle decisions log under the reserved
    # negative phase so replay can never collide with ladder phases
    _, _, live = bandit_run
    phases = {d.phase for d in live.sched_trace.decisions}
    assert sched_mod.SCHED_VCYCLE_PHASE in phases
    assert all(p >= 0 or p == sched_mod.SCHED_VCYCLE_PHASE
               for p in phases)


def test_reward_accounting_telescopes():
    hg = _hg(seed=4)
    cfg = _cfg(sched="bandit", seed=9)      # final_vcycles=0, no budget
    res = impart_partition(hg, cfg)
    trace = res.sched_trace
    assert trace.decisions
    for d in trace.decisions:
        assert d.reward == pytest.approx(
            d.improvement / max(d.wall_s, 1e-9))
    # re-derive the initial population's best cut the way the driver
    # builds it: improvements telescope from there to the final best
    from repro.core.dcoarsen import build_hierarchy
    from repro.core.initial_partition import initial_partition_population
    hier = build_hierarchy(
        hg, cfg.k, seed=cfg.seed,
        contraction_limit_factor=cfg.contraction_limit_factor)
    num = hier.num_levels
    _, init_cuts = initial_partition_population(
        hier.level_host(num - 1), cfg.k, cfg.eps,
        seeds=[cfg.seed * 101 + i for i in range(cfg.alpha)],
        tries_per_strategy=1, hga=hier.level_arrays(num - 1))
    total = sum(d.improvement for d in trace.decisions)
    assert total == pytest.approx(float(np.min(init_cuts)) - res.cut)
    # the histogram is the decisions, aggregated
    hist = trace.histogram()
    assert sum(v["pulls"] for v in hist.values()) == len(trace.decisions)


# --------------------------------------------------------------------------
# scheduler state: exact snapshot/restore
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sched_mod.POLICIES)
def test_state_roundtrip_preserves_stream(policy):
    menu = list(sched_mod.ARMS)
    a = OperatorScheduler(seed=11, policy=policy)
    for i in range(6):
        arm = a.choose(i % 2, 0, menu)
        a.observe(i % 2, 0, arm, improvement=float(i), wall_s=0.5)
    state = json.loads(json.dumps(a.state_dict()))  # JSON-able
    b = OperatorScheduler.from_state(state)
    assert b.state_dict() == a.state_dict()
    for i in range(6):                      # same stream from here on
        arm_a = a.choose(i % 3, 1, menu)
        arm_b = b.choose(i % 3, 1, menu)
        assert arm_a == arm_b
        a.observe(i % 3, 1, arm_a, improvement=1.0, wall_s=0.25)
        b.observe(i % 3, 1, arm_b, improvement=1.0, wall_s=0.25)
    assert b.state_dict() == a.state_dict()


# --------------------------------------------------------------------------
# serving: per-slot scheduler rides the checkpoint through device loss
# --------------------------------------------------------------------------
def test_service_bandit_snapshot_restore():
    from repro.data.hypergraphs import _modular_netlist
    from repro.runtime.elastic import restore_device_pool
    from repro.serve import faults
    from repro.serve.partition_service import (PartitionRequest,
                                               PartitionService)
    try:
        plan = faults.FaultPlan.parse("2:device_loss:survivors=1")
        svc = PartitionService(slots=2, alpha=2, lp_iters=4,
                               contraction_limit_factor=16,
                               ckpt_every=1, fault_plan=plan,
                               sched="bandit")
        reqs = []
        for i in range(2):
            hg = _modular_netlist(360 + 40 * i, 460 + 50 * i,
                                  seed=20 + i, n_modules=5,
                                  p_local=0.8, fanout_tail=1.5)
            reqs.append(PartitionRequest(name=f"sched-svc-{i}", hg=hg,
                                         k=3, eps=0.08, seed=i))
            svc.submit(reqs[-1])
        svc.drain()
        losses = [e for e in svc.events if e["kind"] == "device_loss"]
        assert losses and losses[0]["resumed_from_ckpt"] == 2
        # the snapshot carried mid-flight scheduler state: the resumed
        # slots kept training (decisions recorded before AND after the
        # loss), and the answers are structurally valid
        for i, req in enumerate(reqs):
            res = svc.results[req.name]
            assert res.status == "recovered"
            assert res.part.shape == (req.hg.n,)
            assert np.isfinite(res.cut)
        # the checkpoint meta itself holds a restorable scheduler state
        items, extra = svc._latest_snapshot()
        metas = list(extra["slots"].values())
        assert metas and all(m["sched"] is not None for m in metas)
        restored = OperatorScheduler.from_state(metas[0]["sched"])
        assert restored.trace.decisions  # it had trained mid-flight
    finally:
        restore_device_pool()
