#!/usr/bin/env python3
"""Benchmark artifact gate (CI; pure stdlib, no jax needed).

Two modes:

1. **Committed mode** (no arguments) — validate every `BENCH_*.json`
   committed at the repo root: the top-level key set must match the
   schema recorded here (a writer growing or renaming fields without
   updating this table and `docs/reference.md` fails CI instead of
   silently drifting), every parity flag the writer asserts-before-write
   must actually be `true` in the artifact, and every file must have a
   row in the `docs/reference.md` artifact table.
2. **Regression mode** (`--baseline DIR --candidate DIR`) — validate
   the candidate artifacts as above, then compare every cut-like
   numeric field against the same-named baseline artifact: a candidate
   cut more than `--tolerance` (relative) above the baseline fails.
   Wall-clock fields are NOT compared (CI machines are too noisy);
   cuts are deterministic at fixed seeds, so a cut regression is a
   code regression.

Parity-flag paths use `.` for dict descent and `[*]` for "every list
element" (`sweep[*].exact` = the `exact` bit of every sweep row).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# filename -> {required: top-level keys that must be present,
#              optional: additionally allowed top-level keys,
#              parity: dotted flag paths that must be truthy}
SCHEMAS = {
    "BENCH_population.json": {
        "required": {"alpha", "batched_wall_s", "bench", "cuts_equal",
                     "design", "eps", "fm_node_limit", "k", "levels",
                     "looped_wall_s", "lp_iters", "m", "n",
                     "per_member_cuts", "shard", "speedup"},
        "optional": set(),
        "parity": ["cuts_equal", "shard.cuts_equal"],
    },
    "BENCH_gain.json": {
        "required": {"backend", "bench", "design", "interpret", "m", "n",
                     "pins", "reps", "sweep"},
        "optional": set(),
        "parity": ["sweep[*].exact"],
    },
    "BENCH_coarsen.json": {
        "required": {"backend", "bench", "design", "device_levels",
                     "device_speedup", "device_wall_s", "host_levels",
                     "host_wall_s", "interpret", "k", "m", "n", "note",
                     "pins", "rating_path", "reps"},
        "optional": set(),
        "parity": [],  # tie-breaking differs by design; see the note
    },
    "BENCH_mutation.json": {
        "required": {"alpha_flagged", "backend", "batched_wall_s", "bench",
                     "design", "eps", "interpret", "k",
                     "legacy_per_member_wall_s", "looped_wall_s", "m", "n",
                     "note", "parts_equal", "per_member_cuts", "pins",
                     "speedup", "speedup_vs_legacy"},
        "optional": set(),
        "parity": ["parts_equal"],
    },
    "BENCH_service.json": {
        "required": {"alpha", "bench", "cuts_equal", "lp_iters",
                     "multi_device", "note", "nreq", "offered_loads_rps",
                     "scale", "single_device", "slots"},
        "optional": set(),
        "parity": ["cuts_equal", "single_device.rows[*].cuts_equal",
                   "multi_device.rows[*].cuts_equal"],
    },
    "BENCH_robustness.json": {
        "required": {"alpha", "backend", "baseline_makespan_s", "bench",
                     "devices", "lp_iters", "note", "nreq", "runs",
                     "slots"},
        "optional": set(),
        "parity": ["runs[*].cuts_equal_all"],
    },
    "BENCH_modelshard.json": {
        "required": {"bench", "budget_bytes", "forced", "note"},
        "optional": set(),
        "parity": ["forced.parity_gate.bit_equal"],
    },
    "BENCH_incremental.json": {
        "required": {"alpha", "bench", "drift_magnitude", "k", "lp_iters",
                     "migration_frac", "multi_device", "note", "scale",
                     "single_device", "steps"},
        "optional": set(),
        "parity": ["single_device.rows[*].migration_within_budget",
                   "multi_device.rows[*].migration_within_budget",
                   "single_device.summary.all_within_budget",
                   "multi_device.summary.all_within_budget"],
    },
    "BENCH_sched.json": {
        "required": {"bench", "note", "policy", "rows", "seed", "smoke",
                     "summary"},
        "optional": set(),
        "parity": ["rows[*].replay_equal"],
    },
}


def _walk_flag(obj, parts, path, errors, filename):
    """Resolve one parity-flag path; every terminal value must be truthy."""
    if not parts:
        if obj is not True:
            errors.append(f"{filename}: parity flag {path} is {obj!r}, "
                          "expected true")
        return
    head, rest = parts[0], parts[1:]
    if head == "[*]":
        if not isinstance(obj, list):
            errors.append(f"{filename}: parity path {path} expects a list "
                          f"at [*], found {type(obj).__name__}")
            return
        if not obj:
            errors.append(f"{filename}: parity path {path} hit an empty "
                          "list — nothing was asserted")
            return
        for item in obj:
            _walk_flag(item, rest, path, errors, filename)
        return
    if not isinstance(obj, dict) or head not in obj:
        errors.append(f"{filename}: parity path {path} missing key "
                      f"{head!r}")
        return
    _walk_flag(obj[head], rest, path, errors, filename)


def _flag_parts(path: str):
    parts = []
    for seg in path.split("."):
        if seg.endswith("[*]"):
            parts.extend([seg[:-3], "[*]"])
        else:
            parts.append(seg)
    return parts


def validate_file(path: Path, errors: list) -> dict:
    name = path.name
    schema = SCHEMAS.get(name)
    if schema is None:
        errors.append(f"{name}: no schema registered in "
                      "scripts/check_bench.py (add one alongside the "
                      "writer and a docs/reference.md row)")
        return {}
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        errors.append(f"{name}: unreadable ({exc})")
        return {}
    keys = set(data)
    missing = schema["required"] - keys
    unknown = keys - schema["required"] - schema["optional"]
    if missing:
        errors.append(f"{name}: missing required keys {sorted(missing)}")
    if unknown:
        errors.append(f"{name}: unknown keys {sorted(unknown)} — update "
                      "the schema here and the docs/reference.md table")
    for flag in schema["parity"]:
        _walk_flag(data, _flag_parts(flag), flag, errors, name)
    return data


def _cut_leaves(obj, path=""):
    """Yield (dotted_path, value) for every numeric leaf whose key names
    a cut (lower-is-better, deterministic at fixed seeds).  Ratios and
    booleans are excluded; list elements are indexed positionally."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _cut_leaves(v, f"{path}.{k}" if path else k)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _cut_leaves(v, f"{path}[{i}]")
    else:
        leaf = path.rsplit(".", 1)[-1]
        leaf = leaf.split("[", 1)[0]
        if ("cut" in leaf.lower() and "ratio" not in leaf.lower()
                and isinstance(obj, (int, float))
                and not isinstance(obj, bool)):
            yield path, float(obj)


def compare_cuts(name: str, baseline: dict, candidate: dict,
                 tolerance: float, errors: list) -> int:
    base = dict(_cut_leaves(baseline))
    cand = dict(_cut_leaves(candidate))
    compared = 0
    for path, bval in sorted(base.items()):
        if path not in cand:
            continue  # row-shape changes are the schema check's problem
        compared += 1
        cval = cand[path]
        if bval >= 0 and cval > bval * (1.0 + tolerance):
            errors.append(
                f"{name}: cut regression at {path}: {cval:g} vs baseline "
                f"{bval:g} (tolerance {tolerance:.0%})")
    return compared


def check_docs_rows(names, errors):
    ref = ROOT / "docs" / "reference.md"
    text = ref.read_text() if ref.exists() else ""
    for name in names:
        if f"`{name}`" not in text:
            errors.append(f"{name}: no row in docs/reference.md's "
                          "BENCH_*.json artifact table")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path, default=None,
                    help="directory of baseline BENCH_*.json artifacts")
    ap.add_argument("--candidate", type=Path, default=None,
                    help="directory of candidate BENCH_*.json artifacts")
    ap.add_argument("--tolerance", type=float, default=0.02,
                    help="relative cut-regression tolerance (default 2%%)")
    args = ap.parse_args(argv)
    if (args.baseline is None) != (args.candidate is None):
        ap.error("--baseline and --candidate must be given together")

    errors: list = []
    if args.candidate is None:
        files = sorted(ROOT.glob("BENCH_*.json"))
        if not files:
            errors.append("no BENCH_*.json artifacts at the repo root")
        for path in files:
            validate_file(path, errors)
        check_docs_rows([p.name for p in files], errors)
        checked = len(files)
    else:
        files = sorted(args.candidate.glob("BENCH_*.json"))
        if not files:
            errors.append(f"no BENCH_*.json artifacts in {args.candidate}")
        checked = 0
        for path in files:
            cand = validate_file(path, errors)
            base_path = args.baseline / path.name
            if not base_path.exists():
                print(f"note: {path.name} has no baseline, schema-only")
                continue
            try:
                base = json.loads(base_path.read_text())
            except (OSError, json.JSONDecodeError) as exc:
                errors.append(f"{path.name}: baseline unreadable ({exc})")
                continue
            checked += compare_cuts(path.name, base, cand,
                                    args.tolerance, errors)

    if errors:
        for err in errors:
            print(f"FAIL: {err}", file=sys.stderr)
        print(f"\ncheck_bench: {len(errors)} error(s)", file=sys.stderr)
        return 1
    mode = ("committed artifacts"
            if args.candidate is None else "cut comparisons")
    print(f"check_bench: OK ({checked} {mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
