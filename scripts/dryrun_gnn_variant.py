"""§Perf C dry-run: IMPart-partitioned gatedgcn × ogb_products vs the
baseline sharding — lowers both at full scale on the single-pod mesh and
prints the roofline terms."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.jaxcompat import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze_hlo
from repro.configs.registry import get_arch, get_opt
from repro.models.gnn_partitioned import make_partitioned_loss
from repro.optim import adamw
from repro.optim.schedule import cosine_with_warmup
from repro.models import gnn as gnn_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--boundary-frac", type=float, default=0.30)
    ap.add_argument("--edge-skew", type=float, default=1.3)
    ap.add_argument("--quantize-halo", action="store_true")
    ap.add_argument("--out", default="reports/dryrun/"
                    "gatedgcn__ogb_products_partitioned__single.json")
    args = ap.parse_args()

    spec = get_arch("gatedgcn")
    cfg = spec.config
    n, e, d_feat = 2449029, 61859140, 100
    shards, n_dp = 16, 16
    n_loc = int(-(-n // shards // 128) * 128)
    b_max = int(-(-int(args.boundary_frac * n_loc) // 128) * 128)
    e_loc = int(-(-int(e * args.edge_skew / shards) // (128 * n_dp))
                * 128 * n_dp)
    e_chunk = e_loc // n_dp
    print(f"n_loc={n_loc} b_max={b_max} (frac {args.boundary_frac}) "
          f"e_chunk={e_chunk}")

    mesh = make_production_mesh(multi_pod=False)
    loss_fn, specs = make_partitioned_loss(
        mesh, cfg, n_loc, b_max, quantize_halo=args.quantize_halo)
    opt_cfg = get_opt("gatedgcn")

    params_sds = jax.eval_shape(
        lambda k: gnn_mod.init_params(cfg, k, d_feat=d_feat,
                                      n_classes=cfg.n_classes),
        jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(
        lambda p: adamw.init(p, opt_cfg), params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}

    sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    batch_sds = {
        "node_feat": sds((shards, n_loc, d_feat), jnp.float32),
        "labels": sds((shards, n_loc), jnp.int32),
        "label_mask": sds((shards, n_loc), jnp.float32),
        "boundary_idx": sds((shards, b_max), jnp.int32),
        "edge_src_ref": sds((shards, n_dp, e_chunk), jnp.int32),
        "edge_dst": sds((shards, n_dp, e_chunk), jnp.int32),
        "edge_mask": sds((shards, n_dp, e_chunk), jnp.float32),
        "edge_feat": sds((shards, n_dp, e_chunk, 1), jnp.float32),
    }

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        lr = cosine_with_warmup(state["opt"]["step"])
        p, o, m = adamw.update(grads, state["opt"], state["params"],
                               opt_cfg, lr)
        return {"params": p, "opt": o}, {"loss": loss, **m}

    state_specs = jax.tree.map(lambda _: P(), state_sds)
    to_sh = lambda tree: jax.tree.map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P))
    batch_specs = {k: specs[k] for k in batch_sds}
    with use_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=(to_sh(state_specs), to_sh(batch_specs)),
            donate_argnums=(0,),
        ).lower(state_sds, batch_sds)
        compiled = lowered.compile()
    hlo = analyze_hlo(compiled.as_text(), [cfg.n_layers])
    mem = compiled.memory_analysis()
    rec = {
        "arch": "gatedgcn", "shape": "ogb_products_partitioned",
        "mesh": "single", "kind": "train", "n_devices": 256,
        "trips": [cfg.n_layers],
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "peak_bytes": getattr(mem, "peak_memory_in_bytes", None)},
        "hlo": hlo, "ok": True,
        "params": {"boundary_frac": args.boundary_frac,
                   "edge_skew": args.edge_skew,
                   "quantize_halo": args.quantize_halo},
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(rec, open(args.out, "w"), indent=1)
    print(f"t_comp={hlo['dot_flops']/197e12:.4f}s "
          f"t_mem={hlo['hbm_bytes']/819e9:.4f}s "
          f"t_coll={hlo['wire_bytes']/50e9:.4f}s")
    print({k: round(v['wire_bytes']/1e9, 2)
           for k, v in hlo["collectives"].items()})


if __name__ == "__main__":
    main()
