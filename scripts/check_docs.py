#!/usr/bin/env python3
"""Docs consistency check (CI docs job; pure stdlib, no jax needed).

Three invariants:

1. **Links resolve** — every relative markdown link in README.md,
   DESIGN.md, ROADMAP.md and docs/*.md points at a file that exists
   (external http(s) links and pure #anchors are skipped).
2. **§ citations resolve** — every ``DESIGN.md §N`` citation in the
   source tree (docstrings are the API reference; DESIGN.md is the
   architecture reference they cite) names a section that actually
   exists in DESIGN.md, so renumbering sections without auditing the
   citations fails CI instead of silently pointing readers wrong.
3. **Doc-file references resolve** — any ``SOMETHING.md`` named in a
   Python docstring/comment exists in the repo (catches references to
   docs that were planned but never written, or later renamed).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

DOC_FILES = [ROOT / "README.md", ROOT / "DESIGN.md", ROOT / "ROADMAP.md",
             *sorted((ROOT / "docs").glob("*.md"))]
SRC_DIRS = [ROOT / "src", ROOT / "benchmarks", ROOT / "examples",
            ROOT / "scripts", ROOT / "tests"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CITE_RE = re.compile(r"DESIGN\.md\s+§(\d+)")
SECTION_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)
MD_REF_RE = re.compile(r"\b([A-Za-z][A-Za-z0-9_/.-]*\.md)\b")


def check_links() -> list:
    errors = []
    for doc in DOC_FILES:
        if not doc.exists():
            errors.append(f"{doc.relative_to(ROOT)}: file missing")
            continue
        for target in LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            path = (doc.parent / target.split("#")[0]).resolve()
            if not path.exists():
                errors.append(
                    f"{doc.relative_to(ROOT)}: broken link -> {target}")
    return errors


def check_design_citations() -> list:
    design = (ROOT / "DESIGN.md").read_text()
    sections = {int(s) for s in SECTION_RE.findall(design)}
    errors = []
    for src_dir in SRC_DIRS:
        for py in sorted(src_dir.rglob("*.py")):
            for num in CITE_RE.findall(py.read_text()):
                if int(num) not in sections:
                    errors.append(
                        f"{py.relative_to(ROOT)}: cites DESIGN.md §{num}, "
                        f"but DESIGN.md has only §{sorted(sections)}")
    return errors


def check_md_references() -> list:
    errors = []
    self_path = Path(__file__).resolve()
    for src_dir in SRC_DIRS:
        for py in sorted(src_dir.rglob("*.py")):
            if py.resolve() == self_path:  # this docstring is all examples
                continue
            for name in set(MD_REF_RE.findall(py.read_text())):
                base = name.split("/")[-1]
                if not (list(ROOT.glob(f"**/{base}"))):
                    errors.append(
                        f"{py.relative_to(ROOT)}: references {name}, "
                        "which does not exist anywhere in the repo")
    return errors


def main() -> int:
    errors = check_links() + check_design_citations() + check_md_references()
    for err in errors:
        print(f"check_docs: {err}", file=sys.stderr)
    if errors:
        print(f"check_docs: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n_docs = sum(1 for d in DOC_FILES if d.exists())
    print(f"check_docs: OK ({n_docs} doc files, links + §-citations + "
          "md-references consistent)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
