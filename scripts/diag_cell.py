"""Hillclimb diagnostics: lower a cell, dump the top collectives /
biggest HBM ops with their loop context."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import re
import sys

sys.path.insert(0, "src")

import numpy as np
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import HloModuleStats, COLLECTIVES
from repro.configs.registry import get_arch, get_opt
from repro.train.steps import build_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cell = build_cell(spec, spec.shape(args.shape), False,
                      opt_cfg=get_opt(args.arch), n_devices=256)
    mesh = make_production_mesh(multi_pod=False)
    compiled = cell.lower(mesh).compile()
    text = compiled.as_text()
    if args.save:
        open(args.save, "w").write(text)
    st = HloModuleStats(text)
    trips = cell.static.get("trips", [])

    rows = []

    def walk(comp, mult, depth, path):
        for rec in st.comp_instrs.get(comp, []):
            op, line = rec["op"], rec["line"]
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                t = trips[depth] if depth < len(trips) else 1
                if mb and mb.group(1) in st.comp_instrs:
                    walk(mb.group(1), mult * t, depth + 1,
                         path + f">L{depth}x{t}")
                continue
            if op in COLLECTIVES:
                kind, rb, wire = st._collective_wire(rec, comp)
                meta = re.search(r'op_name="([^"]*)"', line)
                rows.append((wire * mult, kind, rb, mult, path,
                             (meta.group(1) if meta else "")[:110]))

    walk(st.entry, 1.0, 0, "entry")
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total scaled wire: {total:.3e} B/device "
          f"({total / 50e9:.1f}s at 50GB/s), {len(rows)} collective sites")
    for wire, kind, rb, mult, path, meta in rows[: args.top]:
        print(f"  {wire:.3e}B  {kind:20s} rb={rb:.2e} x{mult:.0f} "
              f"[{path}]\n      {meta}")


if __name__ == "__main__":
    main()
